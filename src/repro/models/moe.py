"""Mixture-of-Experts FFN: top-k routing with capacity-based sorted dispatch.

TPU adaptation (GShard/Switch style, see DESIGN.md): instead of a CUDA-style
atomics scatter, tokens are routed with ``top_k`` + sort-free positional
bucketing (cumsum over a one-hot expert assignment), gathered into a dense
``(E, capacity, D)`` buffer, processed as batched matmuls on the MXU, and
combined back with a scatter-add.  Gathers carry no FLOPs in XLA's cost
model, so the dry-run's HLO FLOPs reflect *active* expert compute
(≈ tokens × top_k × capacity_factor), keeping the roofline analysis honest
for MoE architectures.

Capacity drops follow the standard convention: tokens routed beyond
``capacity = tokens · top_k · capacity_factor / E`` for an expert are
dropped for that expert (their gate weight is zeroed); the residual stream
still carries them forward.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

from .common import ModelConfig


def moe_forward(p, x, cfg: ModelConfig):
    if cfg.moe_impl == "local":
        return moe_forward_local(p, x, cfg)
    if cfg.moe_impl == "shmap":
        return moe_forward_shmap(p, x, cfg)
    return moe_forward_global(p, x, cfg)


def moe_forward_global(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D); p: router/w_gate/w_up/w_down (see specs)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    xt = x.reshape(N, D)

    # --- routing -----------------------------------------------------------
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))    # (N, E)
    gates, experts = jax.lax.top_k(logits, K)                               # (N, K)
    gates = jax.nn.softmax(gates, axis=-1)                                  # renorm over top-k

    capacity = int(max(1, round(N * K * cfg.capacity_factor / E)))

    # --- positional bucketing (no atomics): position of token-slot (n, k)
    # within its expert = number of earlier slots routed to the same expert.
    flat_expert = experts.reshape(-1)                                       # (N*K,)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)                # (N*K, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)                   # exclusive
    pos = jnp.take_along_axis(pos_in_expert, flat_expert[:, None], axis=1)[:, 0]
    keep = pos < capacity
    slot = flat_expert * capacity + jnp.where(keep, pos, 0)                 # (N*K,)

    # --- dispatch: dense (E*capacity, D) buffer ------------------------------
    buf = jnp.zeros((E * capacity, D), xt.dtype)
    src = jnp.repeat(xt, K, axis=0)                                         # (N*K, D)
    src = jnp.where(keep[:, None], src, 0)
    buf = buf.at[slot].add(src, mode="drop")                                # scatter-add (no FLOP-heavy op)
    he = buf.reshape(E, capacity, D)

    # --- expert compute (batched SwiGLU on the MXU) --------------------------
    g = jnp.einsum("ecd,edf->ecf", he, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", he, p["w_up"])
    hidden = jax.nn.silu(g) * u
    out_e = jnp.einsum("ecf,efd->ecd", hidden, p["w_down"])                 # (E, cap, D)

    # --- combine: gather slots back, weight by gates, sum over k ------------
    flat_out = out_e.reshape(E * capacity, D)
    tok_out = jnp.take(flat_out, slot, axis=0)                              # (N*K, D)
    w = (gates.reshape(-1) * keep.astype(gates.dtype))[:, None].astype(tok_out.dtype)
    combined = (tok_out * w).reshape(N, K, D).sum(axis=1)
    return combined.reshape(B, S, D)


def moe_forward_local(p, x, cfg: ModelConfig):
    """Row-local double-scatter dispatch (§Perf variant "moe_local").

    Iteration log (EXPERIMENTS.md §Perf): the first attempt kept the global
    formulation's gather-combine; with expert-sharded buffers GSPMD lowers a
    gather from a sharded operand as a full all-gather of the expert buffers
    (measured 5x WORSE than baseline).  This formulation uses scatters in
    BOTH directions — scatter-to-dispatch and scatter-add-to-combine — whose
    updates and indices are replicated across the model axis (activations are
    model-replicated between layers), so each model shard masks its local
    expert range and the only cross-device traffic is the final partial-sum
    all-reduce of the (B, S, D) output — tokens x d_model, independent of
    top_k and capacity.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    cap = int(max(1, round(S * K * cfg.capacity_factor / E)))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates, experts = jax.lax.top_k(logits, K)                  # (B,S,K)
    gates = jax.nn.softmax(gates, axis=-1)

    flat_e = experts.reshape(B, S * K)                         # (B,S*K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (B,S*K,E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot             # exclusive, per row
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, E * cap)        # E*cap = dropped
    rows = jnp.arange(B)[:, None]

    # ---- dispatch: scatter token copies into the expert buffer --------------
    src = jnp.repeat(x, K, axis=1)                             # (B,S*K,D)
    buf = jnp.zeros((B, E * cap, D), x.dtype)
    buf = buf.at[rows, slot].add(src, mode="drop")
    he = constrain(buf.reshape(B, E, cap, D),
                   ("batch", "experts", None, "act_embed"))

    # ---- expert compute (E sharded over the model axis) ---------------------
    g = jnp.einsum("becd,edf->becf", he, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", he, p["w_up"])
    out_e = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, p["w_down"])
    out_e = constrain(out_e, ("batch", "experts", None, "act_embed"))

    # ---- combine: scatter-add expert outputs back to token positions --------
    tok_idx = jnp.broadcast_to(jnp.arange(S * K) // K, (B, S * K))
    w_slot = jnp.zeros((B, E * cap), gates.dtype)
    w_slot = w_slot.at[rows, slot].add(gates.reshape(B, S * K), mode="drop")
    tos = jnp.full((B, E * cap), S, jnp.int32)                 # S = dropped sink
    tos = tos.at[rows, slot].set(tok_idx.astype(jnp.int32), mode="drop")
    contrib = out_e.reshape(B, E * cap, D) * w_slot[..., None].astype(x.dtype)
    out = jnp.zeros((B, S + 1, D), x.dtype)
    out = out.at[rows, tos].add(contrib, mode="drop")
    return constrain(out[:, :S], ("batch", "seq", "act_embed"))


def _positions_by_sort(flat_e):
    """Position of each token-copy within its expert's arrival order.

    Equivalent to the exclusive one-hot cumsum but WITHOUT materializing the
    (B, S·K, E) routing tensor (measured ~8e11 bytes/layer for kimi-k2): a
    stable argsort groups copies by expert, positions are distances to the
    segment start, then scattered back to arrival order.  O(S·K log S·K)
    compare traffic, no E factor.
    """
    B, SK = flat_e.shape
    order = jnp.argsort(flat_e, axis=1, stable=True)            # (B,SK)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    idx = jnp.broadcast_to(jnp.arange(SK), (B, SK))
    is_start = jnp.concatenate(
        [jnp.ones((B, 1), bool), se[:, 1:] != se[:, :-1]], axis=1)
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0), axis=1)
    pos_sorted = idx - seg_start
    pos = jnp.zeros_like(flat_e)
    rows = jnp.arange(B)[:, None]
    return pos.at[rows, order].set(pos_sorted)


def _bucketed_expert_math(x, router, w_gate, w_up, w_down, cfg: ModelConfig,
                          e_lo, E_loc):
    """Local math shared by the shard_map body and its meshless fallback:
    route over ALL experts, keep only the local range [e_lo, e_lo+E_loc),
    bucket per batch row, compute, scatter-add back (partial output)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    cap = int(max(1, round(S * K * cfg.capacity_factor / E)))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    gates, experts = jax.lax.top_k(logits, K)
    gates = jax.nn.softmax(gates, axis=-1)

    flat_e = experts.reshape(B, S * K)
    pos = _positions_by_sort(flat_e)
    local = (flat_e >= e_lo) & (flat_e < e_lo + E_loc)
    keep = (pos < cap) & local
    slot = jnp.where(keep, (flat_e - e_lo) * cap + pos, E_loc * cap)
    rows = jnp.arange(B)[:, None]

    src = jnp.repeat(x, K, axis=1)
    buf = jnp.zeros((B, E_loc * cap, D), x.dtype)
    buf = buf.at[rows, slot].add(src, mode="drop")
    he = buf.reshape(B, E_loc, cap, D)

    g = jnp.einsum("becd,edf->becf", he, w_gate)
    u = jnp.einsum("becd,edf->becf", he, w_up)
    out_e = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, w_down)

    tok_idx = jnp.broadcast_to(jnp.arange(S * K) // K, (B, S * K)).astype(jnp.int32)
    w_slot = jnp.zeros((B, E_loc * cap), gates.dtype)
    w_slot = w_slot.at[rows, slot].add(gates.reshape(B, S * K), mode="drop")
    tos = jnp.full((B, E_loc * cap), S, jnp.int32)
    tos = tos.at[rows, slot].set(tok_idx, mode="drop")
    contrib = out_e.reshape(B, E_loc * cap, D) * w_slot[..., None].astype(x.dtype)
    out = jnp.zeros((B, S + 1, D), x.dtype)
    out = out.at[rows, tos].add(contrib, mode="drop")
    return out[:, :S]


def moe_forward_shmap(p, x, cfg: ModelConfig):
    """Explicit expert parallelism via shard_map (§Perf variant "moe_shmap").

    Activations between layers are replicated across the model axis, so every
    model rank can route all tokens locally, process the experts it owns, and
    contribute a partial (B, S, D) output — combined with ONE psum over the
    model axis.  Collective cost per layer: exactly one all-reduce of
    tokens × d_model, independent of top_k, capacity factor and expert count
    (vs. GSPMD's gather/scatter lowering, which all-reduces whole expert
    buffers in the backward pass — measured 5x worse than even the global
    baseline; see EXPERIMENTS.md §Perf).
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import current_rules

    rules = current_rules()
    E = cfg.n_experts
    if rules is None or "model" not in rules.mesh.shape or E % rules.mesh.shape["model"]:
        return _bucketed_expert_math(x, p["router"], p["w_gate"], p["w_up"],
                                     p["w_down"], cfg, 0, E)

    mesh = rules.mesh
    M = mesh.shape["model"]
    E_loc = E // M
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    x_spec = P(dp_axes, None, None)
    w_spec = P("model", None, None)

    def body(x_l, router, wg, wu, wd):
        idx = jax.lax.axis_index("model")
        e_lo = idx * E_loc
        out = _bucketed_expert_math(x_l, router, wg, wu, wd, cfg, e_lo, E_loc)
        return jax.lax.psum(out, "model")

    from jax.experimental.shard_map import shard_map
    fn = shard_map(body, mesh=mesh,
                   in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
                   out_specs=x_spec, check_rep=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
