"""Shared model machinery: config, parameter specs, norms, RoPE.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every leaf is
declared through a :class:`Spec` carrying its *logical axis names*; the
distribution layer (``repro.distributed.sharding``) maps logical names to
mesh axes, MaxText-style.  The same spec tree materializes as

* random initializations (``init_params``),
* ``jax.ShapeDtypeStruct`` stand-ins for the dry-run (``param_shapes``),
* logical-axis trees for pjit in/out shardings (``param_axes``).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1               # every Nth layer uses MoE FFN (jamba: 2)
    # attention
    window: int | None = None        # sliding-window attention (h2o-danube)
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl M-RoPE
    # hybrid / ssm
    attn_every: int = 0              # jamba: 1 attention layer per this many (0 = all attn)
    ssm: str | None = None           # "mamba" | "rwkv6"
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    # modality stub
    frontend: str | None = None      # "audio" (musicgen) | "vision" (qwen2-vl)
    n_codebooks: int = 1             # musicgen: 4
    # numerics / structure
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # perf-iteration levers (§Perf variants; defaults = paper-faithful baseline)
    moe_impl: str = "global"           # global | local (double-scatter) | shmap
    attn_f32: bool = True              # f32 attention scores/softmax
    rwkv_bf16: bool = False            # bf16 intra-mixer math in rwkv6
    rwkv_chunk: int = 32               # wkv chunk length (W traffic ~ linear in it)

    # ---- derived -----------------------------------------------------------
    @property
    def period(self) -> int:
        """Layers per scanned group (heterogeneous block period)."""
        p = 1
        if self.attn_every:
            p = self.attn_every
        if self.n_experts and self.moe_every > 1:
            p = max(p, self.moe_every)
        return p

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.period == 0, (self.n_layers, self.period)
        return self.n_layers // self.period

    def layer_kind(self, pos: int) -> dict[str, Any]:
        """Mixer/FFN kinds for period position ``pos``."""
        if self.ssm == "rwkv6":
            mixer = "rwkv6"
        elif self.attn_every and (pos % self.attn_every) != self.attn_every // 2:
            mixer = "mamba"
        else:
            mixer = "attn"
        if self.n_experts and (pos % self.moe_every) == self.moe_every - 1:
            ffn = "moe"
        elif self.ssm == "rwkv6":
            ffn = "rwkv_cmix"
        else:
            ffn = "dense"
        return {"mixer": mixer, "ffn": ffn}

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def n_params(self) -> int:
        shapes = jax.eval_shape(lambda: param_shapes_concrete(self))
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))

    def active_params(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        total = self.n_params()
        if not self.n_experts:
            return total
        shapes = param_specs(self)
        expert_total = 0
        for path, spec in shapes.items():
            if "experts" in spec.axes:
                expert_total += int(np.prod(spec.shape))
        return total - expert_total + int(expert_total * self.top_k / self.n_experts)


@dataclass(frozen=True)
class Spec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"     # normal | zeros | ones | small
    dtype: str | None = None  # override model dtype (e.g. f32 for norms)


# ==========================================================================
# Parameter spec tree
# ==========================================================================

def _attn_specs(cfg: ModelConfig, g: int) -> dict[str, Spec]:
    """g = leading group count (stacked scan layers); 0 = unstacked."""
    D, H, Hk, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    lead = (g,) if g else ()
    la = ("layers",) if g else ()
    return {
        "wq": Spec(lead + (D, H * Dh), la + ("embed", "heads")),
        "wk": Spec(lead + (D, Hk * Dh), la + ("embed", "kv")),
        "wv": Spec(lead + (D, Hk * Dh), la + ("embed", "kv")),
        "wo": Spec(lead + (H * Dh, D), la + ("heads", "embed")),
    }


def _dense_ffn_specs(cfg: ModelConfig, g: int) -> dict[str, Spec]:
    D, F = cfg.d_model, cfg.d_ff
    lead = (g,) if g else ()
    la = ("layers",) if g else ()
    return {
        "w_gate": Spec(lead + (D, F), la + ("embed", "ffn")),
        "w_up": Spec(lead + (D, F), la + ("embed", "ffn")),
        "w_down": Spec(lead + (F, D), la + ("ffn", "embed")),
    }


def _moe_specs(cfg: ModelConfig, g: int) -> dict[str, Spec]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    lead = (g,) if g else ()
    la = ("layers",) if g else ()
    return {
        "router": Spec(lead + (D, E), la + ("embed", None)),
        "w_gate": Spec(lead + (E, D, F), la + ("experts", "embed", "ffn")),
        "w_up": Spec(lead + (E, D, F), la + ("experts", "embed", "ffn")),
        "w_down": Spec(lead + (E, F, D), la + ("experts", "ffn", "embed")),
    }


def _mamba_specs(cfg: ModelConfig, g: int) -> dict[str, Spec]:
    D = cfg.d_model
    Di = cfg.ssm_expand * D
    S, C = cfg.d_state, cfg.d_conv
    lead = (g,) if g else ()
    la = ("layers",) if g else ()
    dt_rank = max(D // 16, 1)
    return {
        "in_proj": Spec(lead + (D, 2 * Di), la + ("embed", "ffn")),
        "conv_w": Spec(lead + (C, Di), la + (None, "ffn")),
        "conv_b": Spec(lead + (Di,), la + ("ffn",), init="zeros"),
        "x_proj": Spec(lead + (Di, dt_rank + 2 * S), la + ("ffn", None)),
        "dt_proj": Spec(lead + (dt_rank, Di), la + (None, "ffn")),
        "dt_bias": Spec(lead + (Di,), la + ("ffn",), init="small"),
        "a_log": Spec(lead + (Di, S), la + ("ffn", None), init="small", dtype="float32"),
        "d_skip": Spec(lead + (Di,), la + ("ffn",), init="ones", dtype="float32"),
        "out_proj": Spec(lead + (Di, D), la + ("ffn", "embed")),
    }


def _rwkv_specs(cfg: ModelConfig, g: int) -> dict[str, Spec]:
    D = cfg.d_model
    lead = (g,) if g else ()
    la = ("layers",) if g else ()
    return {
        "mix_r": Spec(lead + (D,), la + ("embed",), init="small"),
        "mix_k": Spec(lead + (D,), la + ("embed",), init="small"),
        "mix_v": Spec(lead + (D,), la + ("embed",), init="small"),
        "mix_w": Spec(lead + (D,), la + ("embed",), init="small"),
        "wr": Spec(lead + (D, D), la + ("embed", "heads")),
        "wk": Spec(lead + (D, D), la + ("embed", "heads")),
        "wv": Spec(lead + (D, D), la + ("embed", "heads")),
        "ww": Spec(lead + (D, D), la + ("embed", "heads")),  # data-dependent decay proj
        "w_bias": Spec(lead + (D,), la + ("heads",), init="small", dtype="float32"),
        "u_bonus": Spec(lead + (D,), la + ("heads",), init="small", dtype="float32"),
        "wo": Spec(lead + (D, D), la + ("heads", "embed")),
        "g_proj": Spec(lead + (D, D), la + ("embed", "heads")),
    }


def _rwkv_cmix_specs(cfg: ModelConfig, g: int) -> dict[str, Spec]:
    D, F = cfg.d_model, cfg.d_ff
    lead = (g,) if g else ()
    la = ("layers",) if g else ()
    return {
        "mix_k": Spec(lead + (D,), la + ("embed",), init="small"),
        "w_k": Spec(lead + (D, F), la + ("embed", "ffn")),
        "w_v": Spec(lead + (F, D), la + ("ffn", "embed")),
    }


def block_specs(cfg: ModelConfig) -> dict[str, dict[str, Spec]]:
    """Specs for one scanned group: per period position, mixer + ffn + norms."""
    g = cfg.n_groups if cfg.scan_layers else 0
    out: dict[str, dict[str, Spec]] = {}
    lead = (g,) if g else ()
    la = ("layers",) if g else ()
    for pos in range(cfg.period):
        kind = cfg.layer_kind(pos)
        sub: dict[str, Any] = {
            "norm_mixer": Spec(lead + (cfg.d_model,), la + ("embed",), init="ones", dtype="float32"),
            "norm_ffn": Spec(lead + (cfg.d_model,), la + ("embed",), init="ones", dtype="float32"),
        }
        if kind["mixer"] == "attn":
            sub["attn"] = _attn_specs(cfg, g)
        elif kind["mixer"] == "mamba":
            sub["mamba"] = _mamba_specs(cfg, g)
        elif kind["mixer"] == "rwkv6":
            sub["rwkv"] = _rwkv_specs(cfg, g)
        if kind["ffn"] == "dense":
            sub["ffn"] = _dense_ffn_specs(cfg, g)
        elif kind["ffn"] == "moe":
            sub["moe"] = _moe_specs(cfg, g)
        elif kind["ffn"] == "rwkv_cmix":
            sub["cmix"] = _rwkv_cmix_specs(cfg, g)
        out[f"pos{pos}"] = sub
    return out


def param_specs(cfg: ModelConfig) -> dict[str, Spec]:
    """Flat ``{'a.b.c': Spec}`` for the whole model."""
    specs: dict[str, Spec] = {}

    def rec(prefix: str, tree):
        for k, v in tree.items():
            path = f"{prefix}.{k}" if prefix else k
            if isinstance(v, Spec):
                specs[path] = v
            else:
                rec(path, v)

    top: dict[str, Any] = {}
    if cfg.frontend == "audio":
        # stub frontend: frame embeddings arrive precomputed; per-codebook
        # output heads remain
        top["heads_out"] = Spec((cfg.n_codebooks, cfg.d_model, cfg.vocab_size),
                                (None, "embed", "vocab"))
    else:
        top["embed"] = Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"))
        if not cfg.tie_embeddings:
            top["lm_head"] = Spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    top["final_norm"] = Spec((cfg.d_model,), ("embed",), init="ones", dtype="float32")
    top["blocks"] = block_specs(cfg)
    rec("", top)
    return specs


def _unflatten(flat: dict[str, Any]) -> dict[str, Any]:
    tree: dict[str, Any] = {}
    for path, v in flat.items():
        node = tree
        parts = path.split(".")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _init_leaf(key, spec: Spec, cfg: ModelConfig):
    dt = jnp.dtype(spec.dtype) if spec.dtype else cfg.jdtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "small":
        return (0.01 * jax.random.normal(key, spec.shape, jnp.float32)).astype(dt)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(dt)


def init_params(cfg: ModelConfig, key) -> dict:
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    flat = {p: _init_leaf(k, s, cfg) for (p, s), k in zip(specs.items(), keys)}
    return _unflatten(flat)


def param_shapes_concrete(cfg: ModelConfig) -> dict:
    specs = param_specs(cfg)
    flat = {p: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype) if s.dtype else cfg.jdtype)
            for p, s in specs.items()}
    return _unflatten(flat)


def param_axes(cfg: ModelConfig) -> dict:
    specs = param_specs(cfg)
    return _unflatten({p: s.axes for p, s in specs.items()})


# ==========================================================================
# numerics helpers
# ==========================================================================

def rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x (..., S, H, Dh), positions (..., S) int -> rotated x."""
    Dh = x.shape[-1]
    freqs = rope_freqs(Dh, theta)                          # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    x1, x2 = x[..., : Dh // 2], x[..., Dh // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: tuple[int, ...]):
    """Qwen2-VL M-RoPE: positions3 (3, ..., S); head-dim halves split into
    ``sections`` (temporal/height/width) each rotated by its own stream."""
    Dh = x.shape[-1]
    half = Dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(Dh, theta)                          # (half,)
    # build per-frequency position selector
    sel = []
    for i, s in enumerate(sections):
        sel += [i] * s
    sel = jnp.asarray(sel)                                  # (half,)
    pos = jnp.take(positions3, sel, axis=0)                 # (half, ..., S)
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)      # (..., S, half)
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def cross_entropy(logits, labels, ignore_id: int = -1):
    """Mean token cross-entropy in float32; labels == ignore_id are masked."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
