"""RWKV-6 ("Finch") attention-free mixer with data-dependent decay.

The defining Finch feature — the per-channel, *data-dependent* decay
``w_t = exp(-exp(proj(x_t) + bias))`` — is implemented exactly.  (The LoRA
parameterization Finch uses for its token-shift mixing coefficients is
simplified to learned static mixes; noted in DESIGN.md §Assumptions.)

Training/prefill runs a *chunked* linear-attention formulation: within a
chunk of 32 tokens the decay products are materialized in log space and the
intra-chunk interaction is two MXU matmuls; chunks are threaded by
``lax.scan`` carrying the (H, dk, dv) state.  This is the TPU-native
equivalent of the CUDA wkv kernel (no sequential per-token loop, no
data-dependent branching).  A per-token recurrent reference
(:func:`wkv_recurrent_ref`) is the correctness oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, rmsnorm

CHUNK = 32


# ---------------------------------------------------------------------------
# WKV core
# ---------------------------------------------------------------------------

def wkv_recurrent_ref(r, k, v, w, u, s0):
    """Token-by-token oracle.  r/k/v/w: (B, L, H, N); u: (H, N);
    s0: (B, H, N, N) mapping k-dim -> v-dim.  Returns (y, s_final)."""
    def step(s, inp):
        rt, kt, vt, wt = inp                                # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, y
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin


def wkv_chunked(r, k, v, w, u, s0, chunk: int = CHUNK, compute_dtype=jnp.float32):
    """Chunked parallel form; same signature/semantics as the oracle.

    ``compute_dtype=bfloat16`` (§Perf variant "rwkv_bf16") keeps the O(C²)
    intra-chunk tensors in bf16 — log-decay accumulation and the carried
    state stay f32 for stability."""
    B, L, H, N = r.shape
    pad = (-L) % chunk
    if pad:
        r, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))) for a in (r, k, v))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    Lp = L + pad
    nc = Lp // chunk

    def to_chunks(a):
        return a.reshape(B, nc, chunk, H, N).swapaxes(0, 1)

    rc, kc, vc, wc = (to_chunks(a) for a in (r, k, v, w))

    ct = compute_dtype

    def chunk_step(s, inp):
        rt32, kt32, vt32, wt = (a.astype(jnp.float32) for a in inp)   # (B,C,H,N)
        rt, kt, vt = rt32.astype(ct), kt32.astype(ct), vt32.astype(ct)
        lw = jnp.log(jnp.maximum(wt, 1e-30))
        cum = jnp.cumsum(lw, axis=1)                            # inclusive (f32)
        cume = cum - lw                                         # exclusive
        r_dec = (rt32 * jnp.exp(cume)).astype(ct)               # r_t · prod_{i<t} w_i
        # inter-chunk: state contribution (state stays f32)
        y_inter = jnp.einsum("bchn,bhnm->bchm", r_dec.astype(jnp.float32), s)
        # intra-chunk: pairwise decay in LOG space — cume[t] - cum[s] is the
        # sum of log-decays strictly between s and t, always <= 0, so the
        # exponent never overflows even for near-zero data-dependent decays.
        diff = cume[:, :, None] - cum[:, None, :]               # (B,C,C,H,N)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_), -1)
        W = jnp.where(tri[None, :, :, None, None],
                      jnp.exp(jnp.minimum(diff, 0.0)), 0.0).astype(ct)
        att = jnp.einsum("bchn,bcdhn,bdhn->bhcd", rt, W, kt,
                         preferred_element_type=jnp.float32)    # (B,H,C,C)
        diag = jnp.einsum("bchn,hn,bchn->bch", rt32, u, kt32)   # (B,C,H)
        y = (y_inter
             + jnp.einsum("bhcd,bdhm->bchm", att.astype(ct), vt,
                          preferred_element_type=jnp.float32)
             + diag[..., None] * vt32)
        # state update (total - cum <= 0: safe)
        total = cum[:, -1]                                      # (B,H,N)
        k_fut = (kt32 * jnp.exp(total[:, None] - cum)).astype(ct)
        s_new = jnp.exp(total)[..., None] * s + jnp.einsum(
            "bchn,bchm->bhnm", k_fut, vt, preferred_element_type=jnp.float32)
        return s_new, y

    s_fin, ys = jax.lax.scan(chunk_step, s0.astype(jnp.float32), (rc, kc, vc, wc))
    y = ys.swapaxes(0, 1).reshape(B, Lp, H, N)[:, :L]
    return y, s_fin


# ---------------------------------------------------------------------------
# layer wrappers
# ---------------------------------------------------------------------------

def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / carried ``last`` for t = 0)."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return prev.at[:, 0].set(first[:, 0])


def rwkv_time_mix(p, x, cfg: ModelConfig, state=None):
    """x: (B, L, D).  state: {"shift": (B,D), "wkv": (B,H,N,N)} or None."""
    B, L, D = x.shape
    N = cfg.rwkv_head_dim
    H = D // N
    last = None if state is None else state["shift"]
    xp = _shift(x, last)
    xr = x + p["mix_r"] * (xp - x)
    xk = x + p["mix_k"] * (xp - x)
    xv = x + p["mix_v"] * (xp - x)
    xw = x + p["mix_w"] * (xp - x)
    r = (xr @ p["wr"]).reshape(B, L, H, N)
    k = (xk @ p["wk"]).reshape(B, L, H, N)
    v = (xv @ p["wv"]).reshape(B, L, H, N)
    g = jax.nn.silu(xr @ p["g_proj"])
    # Finch: data-dependent decay
    wl = (xw @ p["ww"]).astype(jnp.float32) + p["w_bias"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wl)).reshape(B, L, H, N)
    u = p["u_bonus"].astype(jnp.float32).reshape(H, N)
    s0 = (jnp.zeros((B, H, N, N), jnp.float32) if state is None else state["wkv"])
    ct = jnp.bfloat16 if cfg.rwkv_bf16 else jnp.float32
    y, s_fin = wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), w, u, s0,
                           chunk=cfg.rwkv_chunk, compute_dtype=ct)
    # per-head normalization (GroupNorm(H) stand-in), then gate
    y = y / jnp.maximum(jnp.sqrt(jnp.mean(y * y, axis=-1, keepdims=True)), 1e-6)
    y = (y.reshape(B, L, D).astype(x.dtype)) * g
    out = y @ p["wo"]
    new_state = {"shift": x[:, -1, :], "wkv": s_fin}
    return out, new_state


def rwkv_channel_mix(p, x, cfg: ModelConfig, state=None):
    last = None if state is None else state["shift"]
    xp = _shift(x, last)
    xk = x + p["mix_k"] * (xp - x)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = k @ p["w_v"]
    return out, {"shift": x[:, -1, :]}


def rwkv_init_state(cfg: ModelConfig, batch: int, dtype):
    D = cfg.d_model
    N = cfg.rwkv_head_dim
    H = D // N
    return {
        "att": {"shift": jnp.zeros((batch, D), dtype),
                "wkv": jnp.zeros((batch, H, N, N), jnp.float32)},
        "cmix": {"shift": jnp.zeros((batch, D), dtype)},
    }
