"""Model assembly: decoder LM covering all 10 assigned architecture families.

One code path, driven by :class:`ModelConfig`:

* dense / MoE transformers (GQA, RoPE, SWA, M-RoPE),
* Jamba-style hybrids (Mamba mixers with periodic attention, periodic MoE),
* RWKV-6 (attention-free),
* stub-frontend modalities (MusicGen audio, Qwen2-VL vision backbone).

Layers are grouped into a period (heterogeneous block) and scanned with
``jax.lax.scan`` over stacked parameters — HLO size is O(period), not
O(n_layers) — with ``jax.checkpoint`` (remat) around the group body.

Three entry points per model, matching the dry-run shapes:

* :func:`forward` / :func:`loss_fn` — training (train_4k),
* :func:`prefill`                    — inference prefill (prefill_32k),
* :func:`decode_step` + :func:`init_cache` — cached single-token decode
  (decode_32k, long_500k).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

from .attention import attn_decode, attn_forward
from .common import ModelConfig, cross_entropy, rmsnorm
from .mamba import mamba_decode, mamba_forward, mamba_init_state
from .moe import moe_forward
from .rwkv import rwkv_channel_mix, rwkv_init_state, rwkv_time_mix


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def _dense_ffn(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def _embed_in(params, cfg: ModelConfig, batch):
    if cfg.frontend == "audio":
        h = batch["embeddings"].astype(cfg.jdtype)          # stub: (B,S,D)
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
    return constrain(h, ("batch", "seq", "act_embed"))


def _positions(cfg: ModelConfig, batch, B, S):
    if cfg.mrope_sections is not None:
        if "positions" in batch:
            return batch["positions"]                        # (3,B,S)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return jnp.broadcast_to(pos[None], (3, B, S))
    if "positions" in batch:
        return batch["positions"]
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


def _logits_out(params, cfg: ModelConfig, h):
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if cfg.frontend == "audio":
        logits = jnp.einsum("bsd,cdv->bscv", h, params["heads_out"])
        return constrain(logits, ("batch", "seq", None, "vocab"))
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return constrain(h @ w, ("batch", "seq", "vocab"))


def _layer_forward(sub, kind, h, cfg: ModelConfig, positions):
    hn = rmsnorm(h, sub["norm_mixer"], cfg.norm_eps)
    if kind["mixer"] == "attn":
        y, _ = attn_forward(sub["attn"], hn, cfg, positions)
    elif kind["mixer"] == "mamba":
        y = mamba_forward(sub["mamba"], hn, cfg)
    else:
        y, _ = rwkv_time_mix(sub["rwkv"], hn, cfg)
    h = constrain(h + y, ("batch", "seq", "act_embed"))
    hn = rmsnorm(h, sub["norm_ffn"], cfg.norm_eps)
    if kind["ffn"] == "dense":
        y = _dense_ffn(sub["ffn"], hn)
    elif kind["ffn"] == "moe":
        y = moe_forward(sub["moe"], hn, cfg)
    else:
        y, _ = rwkv_channel_mix(sub["cmix"], hn, cfg)
    return constrain(h + y, ("batch", "seq", "act_embed"))


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    """Full-sequence logits: (B, S, V) (audio: (B, S, codebooks, V))."""
    h = _embed_in(params, cfg, batch)
    B, S = h.shape[:2]
    positions = _positions(cfg, batch, B, S)
    kinds = [cfg.layer_kind(i) for i in range(cfg.period)]

    def group_body(h, gp):
        for i in range(cfg.period):
            h = _layer_forward(gp[f"pos{i}"], kinds[i], h, cfg, positions)
        return h, None

    if cfg.scan_layers:
        body = jax.checkpoint(group_body) if cfg.remat else group_body
        h, _ = jax.lax.scan(body, h, params["blocks"])
    else:
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["blocks"])
            h, _ = group_body(h, gp)
    return _logits_out(params, cfg, h)


def loss_fn(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    logits = forward(params, cfg, batch)
    if cfg.frontend == "audio":
        return cross_entropy(logits, batch["labels"])        # labels (B,S,C)
    return cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# inference: prefill + cached decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, context: int) -> dict:
    """Decode caches for every scanned group (leading dim = n_groups)."""
    G = cfg.n_groups
    dt = cfg.jdtype
    per_pos: dict[str, Any] = {}
    kv_len = min(context, cfg.window) if cfg.window else context
    for i in range(cfg.period):
        kind = cfg.layer_kind(i)
        if kind["mixer"] == "attn":
            shape = (G, batch_size, cfg.n_kv_heads, kv_len, cfg.head_dim)
            per_pos[f"pos{i}"] = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
        elif kind["mixer"] == "mamba":
            st = mamba_init_state(cfg, batch_size, dt)
            per_pos[f"pos{i}"] = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (G,) + a.shape), st)
        else:
            st = rwkv_init_state(cfg, batch_size, dt)
            per_pos[f"pos{i}"] = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (G,) + a.shape), st)
    return per_pos


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical axes matching :func:`init_cache` (for dry-run shardings)."""
    per_pos: dict[str, Any] = {}
    for i in range(cfg.period):
        kind = cfg.layer_kind(i)
        if kind["mixer"] == "attn":
            ax = ("layers", "cache_batch", "cache_heads", "kv_seq", None)
            per_pos[f"pos{i}"] = {"k": ax, "v": ax}
        elif kind["mixer"] == "mamba":
            per_pos[f"pos{i}"] = {"conv": ("layers", "cache_batch", None, "ffn"),
                                  "ssm": ("layers", "cache_batch", "ffn", None)}
        else:
            per_pos[f"pos{i}"] = {
                "att": {"shift": ("layers", "cache_batch", "act_embed"),
                        "wkv": ("layers", "cache_batch", "cache_heads", None, None)},
                "cmix": {"shift": ("layers", "cache_batch", "act_embed")},
            }
    return per_pos


def decode_step(params, cfg: ModelConfig, cache, batch, pos_idx):
    """One-token decode.  batch: {"tokens": (B,1)} (audio: {"embeddings":
    (B,1,D)}).  pos_idx: scalar int32 absolute position.  Returns (logits
    (B,V) or (B,C,V), new_cache)."""
    h = _embed_in(params, cfg, batch)
    kinds = [cfg.layer_kind(i) for i in range(cfg.period)]

    def group_body(h, gc):
        gp, gcache = gc
        new_cache = {}
        for i in range(cfg.period):
            sub = gp[f"pos{i}"]
            kind = kinds[i]
            c = gcache[f"pos{i}"]
            hn = rmsnorm(h, sub["norm_mixer"], cfg.norm_eps)
            if kind["mixer"] == "attn":
                y, ck, cv = attn_decode(sub["attn"], hn, cfg, c["k"], c["v"], pos_idx)
                new_cache[f"pos{i}"] = {"k": ck, "v": cv}
            elif kind["mixer"] == "mamba":
                y, st = mamba_decode(sub["mamba"], hn, cfg, c)
                new_cache[f"pos{i}"] = st
            else:
                y, att_st = rwkv_time_mix(sub["rwkv"], hn, cfg, state=c["att"])
                new_cache[f"pos{i}"] = {"att": {"shift": att_st["shift"], "wkv": att_st["wkv"]}}
            h = h + y
            hn = rmsnorm(h, sub["norm_ffn"], cfg.norm_eps)
            if kind["ffn"] == "dense":
                y = _dense_ffn(sub["ffn"], hn)
            elif kind["ffn"] == "moe":
                y = moe_forward(sub["moe"], hn, cfg)
            else:
                y, cm_st = rwkv_channel_mix(sub["cmix"], hn, cfg, state=c["cmix"])
                new_cache[f"pos{i}"]["cmix"] = cm_st
            h = h + y
        return h, new_cache

    if cfg.scan_layers:
        h, new_cache = jax.lax.scan(group_body, h, (params["blocks"], cache))
    else:
        outs = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["blocks"])
            gc = jax.tree.map(lambda a: a[g], cache)
            h, nc = group_body(h, (gp, gc))
            outs.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    logits = _logits_out(params, cfg, h)[:, 0]
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch):
    """Prefill: full forward returning last-token logits (cache writes are
    covered by the decode path; prefill lowering exercises the long-context
    attention/mixer compute)."""
    logits = forward(params, cfg, batch)
    if cfg.frontend == "audio":
        return logits[:, -1]
    return logits[:, -1]
